"""Mamba2 (SSD — state-space duality) layer: chunked train/prefill scan and
O(1)/token recurrent decode. [arXiv:2405.21060]

Projections are kept *split* (wz/wx/wB/wC/wdt instead of one fused in_proj)
so tensor parallelism is clean: the wide d_inner tensors shard over the
``model`` axis (per-head sharding falls out since heads = d_inner/headdim),
while the small B/C/dt projections replicate — the SSM analogue of GQA's
"shard Q heads, replicate tiny KV".

The chunked SSD algorithm (chunk length L):
  intra-chunk:  y_t += Σ_{j≤t}  (C_t·B_j) · exp(cum_t − cum_j) · dt_j · x_j
  chunk state:  S_c  = Σ_j exp(cum_L − cum_j) · dt_j · B_j ⊗ x_j
  carry (scan): H_c  = exp(Σ_chunk dA) · H_{c−1} + S_c
  inter-chunk:  y_t += exp(cum_t) · C_t · H_{c−1}
with cum the within-chunk cumulative sum of dA = dt·A (A < 0). Decode keeps
H directly: H ← exp(dA)·H + dt·B⊗x, y = C·H + D·x.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models import layers
from repro.models.params import ParamSpec


def ssm_specs(cfg):
    d, di, n, h = cfg.d_model, cfg.d_inner, cfg.ssm_state, cfg.ssm_heads
    k = cfg.ssm_conv
    return {
        "wz": ParamSpec((d, di), ("embed", "ssm_inner"), "scaled"),
        "wx": ParamSpec((d, di), ("embed", "ssm_inner"), "scaled"),
        "wB": ParamSpec((d, n), ("embed", "ssm_state"), "scaled"),
        "wC": ParamSpec((d, n), ("embed", "ssm_state"), "scaled"),
        "wdt": ParamSpec((d, h), ("embed", "heads"), "scaled"),
        "conv_x": ParamSpec((k, di), ("conv", "ssm_inner"), "scaled"),
        "conv_B": ParamSpec((k, n), ("conv", "ssm_state"), "scaled"),
        "conv_C": ParamSpec((k, n), ("conv", "ssm_state"), "scaled"),
        "A_log": ParamSpec((h,), ("heads",), "zeros"),
        "D": ParamSpec((h,), ("heads",), "ones"),
        "dt_bias": ParamSpec((h,), ("heads",), "zeros"),
        "norm": ParamSpec((di,), ("ssm_inner",), "ones"),
        "out": ParamSpec((di, d), ("ssm_inner", "embed"), "scaled"),
    }


def _causal_conv(x, w, cache=None):
    """Depthwise causal conv over seq. x: (b,s,c), w: (k,c).

    With a cache (b, k-1, c) performs streaming decode (s==1) and returns
    the updated cache; without, pads with zeros (train/prefill).
    """
    k = w.shape[0]
    if cache is None:
        xp = jnp.pad(x, ((0, 0), (k - 1, 0), (0, 0)))
        new_cache = None
    else:
        xp = jnp.concatenate([cache, x], axis=1)
        new_cache = xp[:, -(k - 1):, :] if k > 1 else cache
    out = sum(xp[:, i:i + x.shape[1], :] * w[i] for i in range(k))
    return out, new_cache


def _project(x, p, cfg, key=None):
    """Input/B/C/dt projections through the substrate, one site each.

    ``key`` is None, a raw (2,) key, or per-token (b, s, 2) keys — each
    projection folds its own site salt so the five draws are independent.
    """
    z = layers.dense(x, p["wz"], cfg, layers.site_key(key, "ssm_wz"),
                     site="ssm_wz")
    xin = layers.dense(x, p["wx"], cfg, layers.site_key(key, "ssm_wx"),
                       site="ssm_wx")
    B = layers.dense(x, p["wB"], cfg, layers.site_key(key, "ssm_wB"),
                     site="ssm_wB")
    C = layers.dense(x, p["wC"], cfg, layers.site_key(key, "ssm_wC"),
                     site="ssm_wC")
    dt = jax.nn.softplus(
        layers.dense(x, p["wdt"], cfg, layers.site_key(key, "ssm_wdt"),
                     site="ssm_wdt").astype(jnp.float32)
        + p["dt_bias"].astype(jnp.float32))
    return z, xin, B, C, dt


def ssd_chunked(x, dt, A, B, C, chunk: int):
    """Chunked SSD scan. x: (b,s,h,p); dt: (b,s,h); A: (h,)<0; B,C: (b,s,n)."""
    b, s, h, pdim = x.shape
    n = B.shape[-1]
    L = min(chunk, s)
    # Front-pad to a chunk multiple: zero inputs are exact no-ops for SSD
    # (they add nothing to any state or output — see ssm_block docstring).
    pad = (-s) % L
    if pad:
        x = jnp.pad(x, ((0, 0), (pad, 0), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (pad, 0), (0, 0)))
        B = jnp.pad(B, ((0, 0), (pad, 0), (0, 0)))
        C = jnp.pad(C, ((0, 0), (pad, 0), (0, 0)))
        s = s + pad
    nc = s // L

    def ch(v, extra=()):
        return v.reshape((b, nc, L) + v.shape[2:])

    xc = ch(x).astype(jnp.float32)
    dtc = ch(dt)                                       # (b,nc,L,h)
    Bc = ch(B).astype(jnp.float32)                     # (b,nc,L,n)
    Cc = ch(C).astype(jnp.float32)
    dA = dtc * A                                       # (b,nc,L,h), negative
    cum = jnp.cumsum(dA, axis=2)                       # within-chunk cumsum

    # Intra-chunk (dual / attention-like form). The decay exponent is masked
    # BEFORE exp so non-causal pairs (positive exponents) cannot overflow.
    att = jnp.einsum("bcln,bcjn->bclj", Cc, Bc)        # (b,nc,L,L)
    diff = cum[:, :, :, None, :] - cum[:, :, None, :, :]  # (b,nc,L,L,h)
    causal = jnp.tril(jnp.ones((L, L), bool))[None, None, :, :, None]
    decay = jnp.exp(jnp.where(causal, diff, -jnp.inf))
    w = att[..., None] * decay                         # (b,nc,L,L,h)
    y_intra = jnp.einsum("bcljh,bcjh,bcjhp->bclhp", w, dtc, xc)

    # Chunk states + inter-chunk carry.
    last = cum[:, :, -1:, :]                           # (b,nc,1,h)
    sdecay = jnp.exp(last - cum)                       # (b,nc,L,h)
    S = jnp.einsum("bcln,bclh,bclhp->bchnp", Bc, sdecay * dtc, xc)
    chunk_decay = jnp.exp(last[:, :, 0, :])            # (b,nc,h)

    def carry_step(Hprev, inp):
        Sc, dc = inp
        Hnew = dc[..., None, None] * Hprev + Sc
        return Hnew, Hprev

    H0 = jnp.zeros((b, h, n, pdim), jnp.float32)
    H_final, Hprevs = jax.lax.scan(
        carry_step, H0,
        (jnp.moveaxis(S, 1, 0), jnp.moveaxis(chunk_decay, 1, 0)))
    Hprevs = jnp.moveaxis(Hprevs, 0, 1)                # (b,nc,h,n,p)

    y_inter = jnp.einsum("bcln,bclh,bchnp->bclhp",
                         Cc, jnp.exp(cum), Hprevs)
    y = (y_intra + y_inter).reshape(b, s, h, pdim)
    if pad:
        y = y[:, pad:]
    return y, H_final


def ssm_block(x, p, cfg, key=None, *, cache=None, constrain=None):
    """Full Mamba2 block. Returns (out, new_cache).

    cache semantics: None -> train (no cache out); the string "prefill" ->
    chunked pass that also returns a decode cache (conv tails + final SSD
    state); a dict(conv_x, conv_B, conv_C, state) -> one-token decode.

    Sharding: the SSD time scan is sequential, so the sequence axis CANNOT
    stay TP-sharded inside the block — instead the wide d_inner/head axis
    shards over `model` (the SSM analogue of head-TP) and the constraints
    below pin that layout so the partitioner doesn't reshard the multi-GB
    hidden tensors per layer.
    """
    cst = constrain or (lambda v_, *a: v_)
    b, s, _ = x.shape
    h, pdim, n = cfg.ssm_heads, cfg.ssm_headdim, cfg.ssm_state
    if key is not None and key.ndim == 1 and s > 1:
        # Chunked-pass key folding: one raw key fans out PER SSD CHUNK
        # (position t draws from fold(key, t // ssm_chunk)), so the
        # projections' stochastic draws align with the scan's chunk grid.
        # Decode (s == 1) keeps the raw key — the engine already varies
        # it per tick; per-token (b, s, 2) keys pass through untouched
        # (the paged path folds per absolute position upstream).
        ck = jnp.broadcast_to(jnp.arange(s)[None, :] // cfg.ssm_chunk,
                              (b, s))
        key = layers.fold_keys(jnp.broadcast_to(key, (b, s, 2)), ck)
    z, xin, B, C, dt = _project(x, p, cfg, key)
    z = cst(z, "batch", "seq", "ssm_inner")
    xin = cst(xin, "batch", "seq", "ssm_inner")
    A = -jnp.exp(p["A_log"].astype(jnp.float32))       # (h,) negative

    if cache is None or cache == "prefill":
        k = cfg.ssm_conv
        raw = (xin, B, C)
        xin, _ = _causal_conv(xin, p["conv_x"])
        B, _ = _causal_conv(B, p["conv_B"])
        C, _ = _causal_conv(C, p["conv_C"])
        xin = jax.nn.silu(xin.astype(jnp.float32)).astype(x.dtype)
        xh = cst(xin.reshape(b, s, h, pdim), "batch", "seq", "heads", None)
        y, H_final = ssd_chunked(xh, dt, A, B.astype(jnp.float32),
                                 C.astype(jnp.float32), cfg.ssm_chunk)
        y = y + p["D"].astype(jnp.float32)[None, None, :, None] \
            * xh.astype(jnp.float32)
        if cache == "prefill":
            rx, rB, rC = raw
            new_cache = {
                "conv_x": rx[:, -(k - 1):, :],
                "conv_B": rB[:, -(k - 1):, :],
                "conv_C": rC[:, -(k - 1):, :],
                "state": H_final,
            }
        else:
            new_cache = None
    else:
        xin, cx = _causal_conv(xin, p["conv_x"], cache["conv_x"])
        B, cB = _causal_conv(B, p["conv_B"], cache["conv_B"])
        C, cC = _causal_conv(C, p["conv_C"], cache["conv_C"])
        xin = jax.nn.silu(xin.astype(jnp.float32)).astype(x.dtype)
        xh = xin.reshape(b, 1, h, pdim).astype(jnp.float32)
        dA = jnp.exp(dt[:, 0] * A)                     # (b,h)
        Bf = B[:, 0].astype(jnp.float32)               # (b,n)
        Cf = C[:, 0].astype(jnp.float32)
        state = cache["state"]                         # (b,h,n,p)
        state = dA[..., None, None] * state + jnp.einsum(
            "bn,bh,bhp->bhnp", Bf, dt[:, 0], xh[:, 0])
        y = jnp.einsum("bn,bhnp->bhp", Cf, state)[:, None]
        y = y + p["D"].astype(jnp.float32)[None, None, :, None] * xh
        new_cache = {"conv_x": cx, "conv_B": cB, "conv_C": cC, "state": state}

    y = y.reshape(b, s, cfg.d_inner).astype(x.dtype)
    y = cst(y, "batch", "seq", "ssm_inner")
    y = y * jax.nn.silu(z.astype(jnp.float32)).astype(x.dtype)   # gate
    y = layers.rms_norm(y, p["norm"])
    okey = layers.site_key(key, "ssm_out")
    return layers.dense(y, p["out"], cfg, okey, site="ssm_out"), new_cache


def ssm_stream(x, p, cfg, key, cache, valid):
    """Chunk-width-invariant SSM feed for the paged engine.

    Scans :func:`ssm_block`'s one-token recurrent update over the chunk
    axis, merging the cache only at VALID positions — so a request's
    state (and therefore its tokens) is bit-identical whether its
    context arrives in one chunk, many chunks, or is replayed after an
    eviction: token t's update is always the same FP op sequence
    ``f(state_{t-1}, x_t)``, never a reassociated chunked scan.  Invalid
    positions (chunk padding, idle rows) compute and discard — their
    cache merge is a no-op, matching the null-block convention of
    ``attention.paged_scatter``.

    x: (b, sc, d); key: None or per-token (b, sc, 2); cache: the dict
    of :func:`init_ssm_cache`; valid: (b, sc) bool.  Returns
    (y (b, sc, d), new_cache).
    """

    def merge(v, new, old):
        keep = v.reshape((-1,) + (1,) * (old.ndim - 1))
        return jnp.where(keep, new, old)

    def step(carry, inp):
        xt, kt, vt = (inp if key is not None
                      else (inp[0], None, inp[1]))   # (b,d), (b,2)|None, (b,)
        yt, nc = ssm_block(xt[:, None], p, cfg, kt, cache=carry)
        nc = jax.tree.map(lambda new, old: merge(vt, new, old), nc, carry)
        return nc, yt[:, 0]

    xs = ((jnp.moveaxis(x, 1, 0), jnp.moveaxis(valid, 1, 0))
          if key is None else
          (jnp.moveaxis(x, 1, 0), jnp.moveaxis(key, 1, 0),
           jnp.moveaxis(valid, 1, 0)))
    new_cache, y = jax.lax.scan(step, cache, xs)
    return jnp.moveaxis(y, 0, 1), new_cache


def init_ssm_cache(cfg, batch: int, dtype=jnp.float32):
    k, di, n = cfg.ssm_conv, cfg.d_inner, cfg.ssm_state
    return {
        "conv_x": jnp.zeros((batch, k - 1, di), dtype),
        "conv_B": jnp.zeros((batch, k - 1, n), dtype),
        "conv_C": jnp.zeros((batch, k - 1, n), dtype),
        "state": jnp.zeros((batch, cfg.ssm_heads, n, cfg.ssm_headdim),
                           jnp.float32),
    }
