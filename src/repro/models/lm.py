"""LM assembly: block definitions, scan-over-layers, prefill and decode.

One code path serves all ten assigned architectures:

  dense / audio / vlm : N × (RMSNorm → GQA attn → RMSNorm → SwiGLU MLP)
  moe                 : N × (RMSNorm → GQA attn → RMSNorm → MoE FFN)
  ssm                 : N × (RMSNorm → Mamba2/SSD block)
  hybrid (zamba2)     : groups of ``attn_every`` Mamba2 layers followed by
                        ONE weight-shared (attn + MLP) block; the scan runs
                        over groups so each shared invocation has a static
                        slot for its own KV cache (zamba2: 81 "layers" =
                        54 ssm + 27 shared invocations, attn_every=2).

Layers are stacked and scanned (``jax.lax.scan`` + per-layer remat), so
compile time and HLO size are O(1) in depth — a 48-layer 400B config lowers
as fast as a 2-layer smoke config. The per-layer PRNG for the SC engine is
folded from the layer index inside the scan.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models import attention, frontend, layers, moe, ssm
from repro.models.params import ParamSpec, tree_map_specs


# --------------------------------------------------------------------------
# Param specs
# --------------------------------------------------------------------------


def _norm_spec(cfg):
    return ParamSpec((cfg.d_model,), ("embed",), "ones")


def block_specs(cfg):
    if cfg.family in ("ssm", "hybrid"):
        return {"ln1": _norm_spec(cfg), "ssm": ssm.ssm_specs(cfg)}
    ffn = moe.moe_specs(cfg) if cfg.family == "moe" else layers.mlp_specs(cfg)
    return {"ln1": _norm_spec(cfg), "attn": attention.attn_specs(cfg),
            "ln2": _norm_spec(cfg), "ffn": ffn}


def shared_block_specs(cfg):
    """zamba2's weight-shared transformer block (MHA + MLP)."""
    return {"ln1": _norm_spec(cfg), "attn": attention.attn_specs(cfg),
            "ln2": _norm_spec(cfg), "mlp": layers.mlp_specs(cfg)}


def stack_specs(specs, n: int):
    return tree_map_specs(
        lambda s: ParamSpec((n,) + s.shape, ("layers",) + s.axes, s.init,
                            s.dtype), specs)


def n_backbone_layers(cfg) -> int:
    """Scanned backbone depth (hybrid: ssm layers only; `n_layers` counts
    ssm layers + shared invocations)."""
    if cfg.family == "hybrid":
        return cfg.n_layers * cfg.attn_every // (cfg.attn_every + 1)
    return cfg.n_layers


def n_shared_invocations(cfg) -> int:
    if cfg.family != "hybrid":
        return 0
    return n_backbone_layers(cfg) // cfg.attn_every


def lm_param_specs(cfg):
    sp = {
        "embed": layers.embed_specs(cfg),
        "blocks": stack_specs(block_specs(cfg), n_backbone_layers(cfg)),
        "final_norm": _norm_spec(cfg),
    }
    if not cfg.tie_embeddings:
        sp["unembed"] = ParamSpec((cfg.d_model, cfg.vocab),
                                  ("embed", "vocab"), "scaled")
    if cfg.family == "hybrid":
        sp["shared"] = shared_block_specs(cfg)
    if cfg.frontend == "embeddings":
        sp["frontend"] = frontend.frontend_specs(cfg)
    return sp


def _logits(x, params, cfg, key=None):
    """Output projection (site ``unembed``): ``key`` is the caller's rng
    root — raw (2,) or per-row (..., 2) matching ``x``'s leading dims —
    folded here with the unembed site salt."""
    key = layers.site_key(key, "unembed")
    if cfg.tie_embeddings:
        return layers.unembed(x, params["embed"], cfg, key).astype(
            jnp.float32)
    return layers.dense(x, params["unembed"], cfg, key,
                        site="unembed").astype(jnp.float32)


def _group(tree, ninv: int, per: int):
    """Reshape stacked-layer leaves (n, ...) -> (ninv, per, ...)."""
    return jax.tree.map(lambda v: v.reshape((ninv, per) + v.shape[1:]), tree)


# --------------------------------------------------------------------------
# Block application
# --------------------------------------------------------------------------


def _apply_block(x, p, cfg, positions, key, cache=None, cache_length=None,
                 cst=None):
    """One backbone block (pre-norm residual). Returns (x, new_cache)."""
    if cfg.family in ("ssm", "hybrid"):
        h, new_cache = ssm.ssm_block(layers.rms_norm(x, p["ln1"]), p["ssm"],
                                     cfg, key, cache=cache, constrain=cst)
        return x + h, new_cache
    akey = None if key is None else jax.random.fold_in(key, 11)
    h, new_cache = attention.attention_block(
        layers.rms_norm(x, p["ln1"]), p["attn"], cfg, positions, akey,
        cache=cache, cache_length=cache_length, constrain=cst)
    x = x + h
    fkey = None if key is None else jax.random.fold_in(key, 13)
    if cfg.family == "moe":
        h = moe.moe_ffn(layers.rms_norm(x, p["ln2"]), p["ffn"], cfg, fkey,
                        constrain=cst)
    else:
        h = layers.mlp(layers.rms_norm(x, p["ln2"]), p["ffn"], cfg, fkey,
                       constrain=cst)
    return x + h, new_cache


def _apply_shared(x, p, cfg, positions, key, cache=None, cache_length=None,
                  cst=None):
    akey = None if key is None else jax.random.fold_in(key, 17)
    h, new_cache = attention.attention_block(
        layers.rms_norm(x, p["ln1"]), p["attn"], cfg, positions, akey,
        cache=cache, cache_length=cache_length, constrain=cst)
    x = x + h
    mkey = None if key is None else jax.random.fold_in(key, 19)
    x = x + layers.mlp(layers.rms_norm(x, p["ln2"]), p["mlp"], cfg, mkey,
                       constrain=cst)
    return x, new_cache


def _maybe_remat(fn, cfg):
    return jax.checkpoint(fn) if cfg.remat == "full" else fn


# --------------------------------------------------------------------------
# Forward (train / eval over a full sequence)
# --------------------------------------------------------------------------


def _embed_inputs(params, inputs, cfg, rng=None):
    if cfg.frontend == "embeddings" and inputs.ndim == 3:
        x = inputs.astype(cfg.act_dtype)
        if "frontend" in params:
            x = frontend.project_embeddings(x, params["frontend"], cfg, rng)
        return x
    return layers.embed(inputs, params["embed"]).astype(cfg.act_dtype)


def encode(params, inputs, cfg, *, rng=None, constrain=None,
           constrain_params=None):
    """Backbone pass: inputs (tokens or stub embeddings) -> final hidden
    states (b, s, d) after the last norm."""
    cst = constrain or (lambda v, *a: v)
    cstp = constrain_params or (lambda t: t)
    x = _embed_inputs(params, inputs, cfg, rng)
    b, s = x.shape[:2]
    x = cst(x, "batch", "resid_seq", None)
    positions = jnp.broadcast_to(jnp.arange(s)[None], (b, s))

    if cfg.family == "hybrid":
        ninv, per = n_shared_invocations(cfg), cfg.attn_every
        grouped = _group(params["blocks"], ninv, per)

        def gbody(carry, group_params):
            xc, idx = carry
            for j in range(per):
                lp = cstp(jax.tree.map(lambda v: v[j], group_params))
                key = None if rng is None else jax.random.fold_in(rng, idx * per + j)
                xc, _ = _apply_block(xc, lp, cfg, positions, key, cst=cst)
            k2 = None if rng is None else jax.random.fold_in(rng, 10_000 + idx)
            xc, _ = _apply_shared(xc, params["shared"], cfg, positions, k2,
                                  cst=cst)
            xc = cst(xc, "batch", "resid_seq", None)
            return (xc, idx + 1), None

        (x, _), _ = jax.lax.scan(_maybe_remat(gbody, cfg), (x, 0), grouped)
    else:
        def body(carry, layer_params):
            xc, idx = carry
            key = None if rng is None else jax.random.fold_in(rng, idx)
            xc, _ = _apply_block(xc, cstp(layer_params), cfg, positions, key,
                                 cst=cst)
            xc = cst(xc, "batch", "resid_seq", None)
            return (xc, idx + 1), None

        (x, _), _ = jax.lax.scan(_maybe_remat(body, cfg), (x, 0),
                                 params["blocks"])

    return layers.rms_norm(x, params["final_norm"])


def forward(params, inputs, cfg, *, rng=None, constrain=None,
            constrain_params=None):
    """Full logits (b, s, vocab). Prefer lm_loss for training: it never
    materializes the whole logits tensor."""
    cst = constrain or (lambda v, *a: v)
    x = encode(params, inputs, cfg, rng=rng, constrain=constrain,
               constrain_params=constrain_params)
    logits = _logits(x, params, cfg, rng)
    return cst(logits, "batch", "seq", "vocab")


LOSS_SEQ_CHUNK = 1024


def lm_loss(params, batch, cfg, *, rng=None, constrain=None,
            constrain_params=None):
    """Causal next-token cross-entropy, sequence-chunked.

    The (tokens, vocab) logits tensor is the largest activation in any LM
    step, and it only feeds a reduction — so the unembed + log-softmax +
    gather runs per sequence chunk inside a remat'd scan: peak memory drops
    from O(s·vocab) to O(chunk·vocab), and the backward recomputes each
    chunk's logits instead of keeping them alive.
    """
    x = encode(params, batch["inputs"], cfg, rng=rng, constrain=constrain,
               constrain_params=constrain_params)
    labels = batch["labels"]
    b, s, d = x.shape
    c = min(LOSS_SEQ_CHUNK, s)
    if s % c:
        c = s                      # irregular lengths: single chunk
    nc = s // c
    xc = jnp.moveaxis(x.reshape(b, nc, c, d), 1, 0)
    lc = jnp.moveaxis(labels.reshape(b, nc, c), 1, 0)

    @jax.checkpoint
    def chunk_nll(carry, inp):
        tot, i = carry
        xi, li = inp                               # (b,c,d), (b,c)
        key = None if rng is None else jax.random.fold_in(rng, i)
        logits = _logits(xi, params, cfg, key)     # (b,c,vocab) f32
        logp = jax.nn.log_softmax(logits, axis=-1)
        nll = -jnp.take_along_axis(logp, li[..., None], axis=-1)[..., 0]
        return (tot + nll.sum(), i + 1), None

    (total, _), _ = jax.lax.scan(
        chunk_nll, (jnp.zeros((), jnp.float32), 0), (xc, lc))
    return total / (b * s)


# --------------------------------------------------------------------------
# KV / SSM cache
# --------------------------------------------------------------------------


def init_cache(cfg, batch: int, max_len: int, dtype=None):
    """Stacked per-layer decode cache (leading axis = backbone layer or
    shared invocation)."""
    dtype = dtype or cfg.act_dtype
    n = n_backbone_layers(cfg)
    kvh, hd = cfg.n_kv_heads, cfg.resolved_head_dim
    if cfg.family in ("ssm", "hybrid"):
        one = ssm.init_ssm_cache(cfg, batch, dtype)
        cache = {"ssm": jax.tree.map(
            lambda v: jnp.broadcast_to(v[None], (n,) + v.shape).copy(), one)}
        if cfg.family == "hybrid":
            ninv = n_shared_invocations(cfg)
            cache["shared_k"] = jnp.zeros((ninv, batch, max_len, kvh, hd),
                                          dtype)
            cache["shared_v"] = jnp.zeros((ninv, batch, max_len, kvh, hd),
                                          dtype)
        return cache
    return {"k": jnp.zeros((n, batch, max_len, kvh, hd), dtype),
            "v": jnp.zeros((n, batch, max_len, kvh, hd), dtype)}


def init_paged_cache(cfg, num_blocks: int, block_size: int, dtype=None,
                     slots: int | None = None):
    """Per-family paged decode cache (the device half of the cache plan —
    ``serve/kv_cache.py:CachePlan``).

    Attention families: one pool of ``num_blocks`` fixed-size token
    blocks per layer, addressed through per-sequence block tables
    (``serve/kv_cache.py`` owns the allocator; block 0 is the reserved
    null block padding writes land in).

    SSM: state is O(1) per sequence — nothing to page.  The cache is one
    fixed-size state + conv-tail row PER BATCH ROW (``slots``), carried
    beside the block table (the block allocator still meters admission/
    eviction token budget; the tables themselves go unused by the model).

    Hybrid: both — SSM state rows for the backbone layers plus paged K/V
    pools for the weight-shared attention invocations.
    """
    dtype = dtype or cfg.act_dtype
    n = n_backbone_layers(cfg)
    kvh, hd = cfg.n_kv_heads, cfg.resolved_head_dim
    if cfg.family in ("ssm", "hybrid"):
        if slots is None:
            raise ValueError(
                f"family={cfg.family!r} carries fixed-size SSM state per "
                "batch row — pass slots= to init_paged_cache")
        one = ssm.init_ssm_cache(cfg, slots, dtype)
        pages = {"ssm": jax.tree.map(
            lambda v: jnp.broadcast_to(v[None], (n,) + v.shape).copy(), one)}
        if cfg.family == "hybrid":
            ninv = n_shared_invocations(cfg)
            pages["k"] = jnp.zeros((ninv, num_blocks, block_size, kvh, hd),
                                   dtype)
            pages["v"] = jnp.zeros((ninv, num_blocks, block_size, kvh, hd),
                                   dtype)
        return pages
    return {"k": jnp.zeros((n, num_blocks, block_size, kvh, hd), dtype),
            "v": jnp.zeros((n, num_blocks, block_size, kvh, hd), dtype)}


def _reset_fresh_state(cache, lengths):
    """Zero the SSM state/conv rows of sequences starting from position 0
    this step (fresh admission or eviction resume) — the recurrent
    analogue of a fresh block table.  cache leaves: (n, b, ...);
    idle rows (lengths == 0, nothing fed) zero harmlessly."""
    fresh = lengths == 0                                  # (b,)

    def z(v):
        m = fresh.reshape((1, -1) + (1,) * (v.ndim - 2))
        return jnp.where(m, jnp.zeros_like(v), v)

    return jax.tree.map(z, cache)


def decode_paged(params, pages, block_table, tokens, lengths, n_valid, cfg,
                 *, rng=None, all_logits: bool = False):
    """One chunked step over the paged KV cache — decode AND prefill.

    tokens: (b, sc) — row r feeds its next ``n_valid[r]`` context tokens
    (decode ticks feed 1; chunked prefill feeds up to sc); positions are
    absolute: token i of row r sits at ``lengths[r] + i``.  Slots beyond
    a row's valid count (chunk padding, idle rows) write their K/V to the
    null block and are masked out of every live query.  Returns
    ``(logits, new_pages)`` with logits (b, vocab) taken at each row's
    LAST VALID position — the next-token distribution once the row's
    pending context is consumed.  With ``all_logits=True`` (a static
    flag — bake it into the jitted partial) logits are (b, sc, vocab),
    one next-token distribution per fed position: the speculative
    verifier reads every drafted position from ONE call.

    RNG contract (what makes continuous batching testable): ``rng`` is a
    (b, 2) array of per-request raw keys.  Inside, every token folds its
    row's key with its ABSOLUTE position, and all layer/call-site folds
    derive from that — so the stochastic bits a token draws depend only on
    (request key, position, layer, call site), never on batch neighbours,
    chunk boundaries, or admission order.  The same request with the same
    key therefore produces identical values served alone, in a full
    batch, or re-prefilled after an eviction.  ``paged_attn="fused_sc"``
    rides the same contract (attention QK^T draws under salt 29), which
    is why it REQUIRES ``rng``.

    Alternatively ``rng`` may be (b, sc, 2) PER-TOKEN keys, already
    resolved by the caller — the scheduler's content-chain mode
    (``rng_mode="content"``, forced by prefix caching) derives token t's
    key from the token CONTENT up to t instead of the request identity,
    so two requests sharing a prompt prefix draw bitwise-identical SC
    bits there and cached KV blocks are safe to share.  Layer/call-site
    folds are identical in both forms.

    SSM / hybrid families ride the same signature with the per-family
    cache plan's pages (``init_paged_cache``): SSM layers feed their
    chunk through :func:`ssm.ssm_stream` — token-recurrent, so a row's
    state is BIT-identical whatever the chunking or batch composition —
    and rows at ``lengths == 0`` (fresh admission or eviction resume)
    zero their state first.  Hybrid adds the weight-shared attention
    block over its own paged K/V pools per invocation.
    """
    if rng is None and getattr(cfg, "paged_attn", "unfused") == "fused_sc":
        raise ValueError("paged_attn='fused_sc' draws stochastic attention "
                         "logits from per-request keys; pass rng=(b, 2) "
                         "raw keys to decode_paged")
    b, sc = tokens.shape
    x = layers.embed(tokens, params["embed"]).astype(cfg.act_dtype)
    positions = lengths[:, None] + jnp.arange(sc)[None, :]      # (b, sc)
    keys = None
    if rng is not None:
        if rng.ndim == 3:
            keys = rng                  # (b, sc, 2) caller-resolved keys
        else:
            per_tok = jnp.broadcast_to(rng[:, None, :],
                                       (b, sc, rng.shape[-1]))
            keys = layers.fold_keys(per_tok, positions)         # (b, sc, 2)
    valid = jnp.arange(sc)[None, :] < n_valid[:, None]          # (b, sc)

    if cfg.family == "ssm":
        ssm_cache = _reset_fresh_state(pages["ssm"], lengths)

        def sbody(carry, scanned):
            xc, idx = carry
            lp, lc = scanned
            lkeys = layers.fold_keys(keys, idx)
            h, nc = ssm.ssm_stream(layers.rms_norm(xc, lp["ln1"]),
                                   lp["ssm"], cfg, lkeys, lc, valid)
            return (xc + h, idx + 1), nc

        (x, _), new_ssm = jax.lax.scan(
            sbody, (x, 0), (params["blocks"], ssm_cache))
        new_pages = {"ssm": new_ssm}
    elif cfg.family == "hybrid":
        ssm_cache = _reset_fresh_state(pages["ssm"], lengths)
        ninv, per = n_shared_invocations(cfg), cfg.attn_every
        grouped = _group(params["blocks"], ninv, per)
        gcache = _group(ssm_cache, ninv, per)

        def gbody(carry, scanned):
            xc, idx = carry
            gp, gc, kp, vp = scanned
            new_ssm = []
            for j in range(per):
                lp = jax.tree.map(lambda v: v[j], gp)
                lc = jax.tree.map(lambda v: v[j], gc)
                lkeys = layers.fold_keys(keys, idx * per + j)
                h, nc = ssm.ssm_stream(layers.rms_norm(xc, lp["ln1"]),
                                       lp["ssm"], cfg, lkeys, lc, valid)
                xc = xc + h
                new_ssm.append(nc)
            new_ssm = jax.tree.map(lambda *vs: jnp.stack(vs), *new_ssm)
            k2 = layers.fold_keys(keys, 10_000 + idx)
            h, kp, vp = attention.paged_attention_block(
                layers.rms_norm(xc, params["shared"]["ln1"]),
                params["shared"]["attn"], cfg, positions,
                layers.fold_keys(k2, 17), kp, vp, block_table, lengths,
                n_valid)
            xc = xc + h
            xc = xc + layers.mlp(
                layers.rms_norm(xc, params["shared"]["ln2"]),
                params["shared"]["mlp"], cfg, layers.fold_keys(k2, 19))
            return (xc, idx + 1), (new_ssm, kp, vp)

        (x, _), (ssm_g, k_new, v_new) = jax.lax.scan(
            gbody, (x, 0), (grouped, gcache, pages["k"], pages["v"]))
        n = n_backbone_layers(cfg)
        new_pages = {"ssm": jax.tree.map(
            lambda v: v.reshape((n,) + v.shape[2:]), ssm_g),
            "k": k_new, "v": v_new}
    else:
        def body(carry, scanned):
            xc, idx = carry
            lp, kp, vp = scanned
            lkeys = layers.fold_keys(keys, idx)
            h, kp, vp = attention.paged_attention_block(
                layers.rms_norm(xc, lp["ln1"]), lp["attn"], cfg, positions,
                layers.fold_keys(lkeys, 11), kp, vp, block_table, lengths,
                n_valid)
            xc = xc + h
            fkey = layers.fold_keys(lkeys, 13)
            if cfg.family == "moe":
                h = moe.moe_ffn(layers.rms_norm(xc, lp["ln2"]), lp["ffn"],
                                cfg, fkey)
            else:
                h = layers.mlp(layers.rms_norm(xc, lp["ln2"]), lp["ffn"],
                               cfg, fkey)
            return (xc + h, idx + 1), (kp, vp)

        (x, _), (k_new, v_new) = jax.lax.scan(
            body, (x, 0), (params["blocks"], pages["k"], pages["v"]))
        new_pages = {"k": k_new, "v": v_new}

    x = layers.rms_norm(x, params["final_norm"])
    if all_logits:
        return _logits(x, params, cfg, keys), new_pages
    last = jnp.maximum(n_valid - 1, 0)
    xl = jnp.take_along_axis(x, last[:, None, None], axis=1)[:, 0]
    lkey = None
    if keys is not None:
        lkey = jnp.take_along_axis(
            keys, last[:, None, None], axis=1)[:, 0]            # (b, 2)
    logits = _logits(xl, params, cfg, lkey)
    return logits, new_pages


# --------------------------------------------------------------------------
# Decode (one token per sequence) — what `serve_step` lowers
# --------------------------------------------------------------------------


def decode_step(params, cache, tokens, lengths, cfg, *, rng=None,
                constrain=None, constrain_params=None):
    """tokens: (b,) next input ids; lengths: (b,) current cache fill (the new
    token writes at that index). Returns (logits (b, vocab), new_cache)."""
    cst = constrain or (lambda v, *a: v)
    cstp = constrain_params or (lambda t: t)
    x = layers.embed(tokens, params["embed"]).astype(cfg.act_dtype)[:, None]
    positions = lengths[:, None]
    new_lengths = lengths + 1

    if cfg.family in ("ssm", "hybrid"):
        if cfg.family == "hybrid":
            ninv, per = n_shared_invocations(cfg), cfg.attn_every
            grouped = _group(params["blocks"], ninv, per)
            gcache = _group(cache["ssm"], ninv, per)

            def gbody(carry, scanned):
                xc, idx = carry
                gp, gc, kc, vc = scanned
                new_ssm = []
                for j in range(per):
                    lp = cstp(jax.tree.map(lambda v: v[j], gp))
                    lc = jax.tree.map(lambda v: v[j], gc)
                    key = (None if rng is None
                           else jax.random.fold_in(rng, idx * per + j))
                    xc, nc = _apply_block(xc, lp, cfg, positions, key,
                                          cache=lc, cst=cst)
                    new_ssm.append(nc)
                new_ssm = jax.tree.map(lambda *vs: jnp.stack(vs), *new_ssm)
                k2 = (None if rng is None
                      else jax.random.fold_in(rng, 10_000 + idx))
                xc, (kc2, vc2) = _apply_shared(
                    xc, params["shared"], cfg, positions, k2, cache=(kc, vc),
                    cache_length=new_lengths, cst=cst)
                return (xc, idx + 1), (new_ssm, kc2, vc2)

            (x, _), (new_ssm_g, k_new, v_new) = jax.lax.scan(
                gbody, (x, 0),
                (grouped, gcache, cache["shared_k"], cache["shared_v"]))
            n = n_backbone_layers(cfg)
            new_cache = {
                "ssm": jax.tree.map(
                    lambda v: v.reshape((n,) + v.shape[2:]), new_ssm_g),
                "shared_k": k_new, "shared_v": v_new,
            }
        else:
            def body(carry, scanned):
                xc, idx = carry
                lp, lc = scanned
                key = None if rng is None else jax.random.fold_in(rng, idx)
                xc, nc = _apply_block(xc, cstp(lp), cfg, positions, key,
                                      cache=lc, cst=cst)
                return (xc, idx + 1), nc

            (x, _), new_ssm = jax.lax.scan(body, (x, 0),
                                           (params["blocks"], cache["ssm"]))
            new_cache = {"ssm": new_ssm}
    else:
        def body(carry, scanned):
            xc, idx = carry
            lp, kc, vc = scanned
            key = None if rng is None else jax.random.fold_in(rng, idx)
            xc, (kc2, vc2) = _apply_block(xc, cstp(lp), cfg, positions, key,
                                          cache=(kc, vc),
                                          cache_length=new_lengths, cst=cst)
            return (xc, idx + 1), (kc2, vc2)

        (x, _), (k_new, v_new) = jax.lax.scan(
            body, (x, 0), (params["blocks"], cache["k"], cache["v"]))
        new_cache = {"k": k_new, "v": v_new}

    x = layers.rms_norm(x, params["final_norm"])
    logits = _logits(x[:, 0], params, cfg, rng)
    return cst(logits, "batch", "vocab"), new_cache


# --------------------------------------------------------------------------
# Prefill — builds the cache from a prompt; what the prefill shapes lower
# --------------------------------------------------------------------------


def prefill(params, inputs, cfg, max_len: int, *, rng=None, constrain=None,
            constrain_params=None):
    """Run the prompt through the model, returning (last-token logits, cache,
    lengths). inputs: (b, s) tokens or (b, s, d) embeddings; s <= max_len."""
    cst = constrain or (lambda v, *a: v)
    cstp = constrain_params or (lambda t: t)
    x = _embed_inputs(params, inputs, cfg, rng)
    b, s = x.shape[:2]
    positions = jnp.broadcast_to(jnp.arange(s)[None], (b, s))
    kvh, hd = cfg.n_kv_heads, cfg.resolved_head_dim

    def pad_kv(kv):
        k, v = kv
        kp = jnp.zeros((b, max_len, kvh, hd), k.dtype)
        vp = jnp.zeros((b, max_len, kvh, hd), v.dtype)
        kp = jax.lax.dynamic_update_slice(kp, k, (0, 0, 0, 0))
        vp = jax.lax.dynamic_update_slice(vp, v, (0, 0, 0, 0))
        return kp, vp

    if cfg.family in ("ssm", "hybrid"):
        if cfg.family == "hybrid":
            ninv, per = n_shared_invocations(cfg), cfg.attn_every
            grouped = _group(params["blocks"], ninv, per)

            def gbody(carry, gp):
                xc, idx = carry
                caches = []
                for j in range(per):
                    lp = cstp(jax.tree.map(lambda v: v[j], gp))
                    key = (None if rng is None
                           else jax.random.fold_in(rng, idx * per + j))
                    xc, nc = _apply_block(xc, lp, cfg, positions, key,
                                          cache="prefill", cst=cst)
                    caches.append(nc)
                ssm_c = jax.tree.map(lambda *vs: jnp.stack(vs), *caches)
                k2 = (None if rng is None
                      else jax.random.fold_in(rng, 10_000 + idx))
                xc, kv = _apply_shared(xc, params["shared"], cfg, positions,
                                       k2, cst=cst)
                kp, vp = pad_kv(kv)
                return (xc, idx + 1), (ssm_c, kp, vp)

            (x, _), (ssm_g, kp, vp) = jax.lax.scan(
                _maybe_remat(gbody, cfg), (x, 0), grouped)
            n = n_backbone_layers(cfg)
            cache = {"ssm": jax.tree.map(
                lambda v: v.reshape((n,) + v.shape[2:]), ssm_g),
                "shared_k": kp, "shared_v": vp}
        else:
            def body(carry, lp):
                xc, idx = carry
                key = None if rng is None else jax.random.fold_in(rng, idx)
                xc, nc = _apply_block(xc, cstp(lp), cfg, positions, key,
                                      cache="prefill", cst=cst)
                return (xc, idx + 1), nc

            (x, _), ssm_c = jax.lax.scan(_maybe_remat(body, cfg), (x, 0),
                                         params["blocks"])
            cache = {"ssm": ssm_c}
    else:
        def body(carry, lp):
            xc, idx = carry
            key = None if rng is None else jax.random.fold_in(rng, idx)
            xc, kv = _apply_block(xc, cstp(lp), cfg, positions, key, cst=cst)
            kp, vp = pad_kv(kv)
            return (xc, idx + 1), (kp, vp)

        (x, _), (k_all, v_all) = jax.lax.scan(_maybe_remat(body, cfg), (x, 0),
                                              params["blocks"])
        cache = {"k": k_all, "v": v_all}

    x = layers.rms_norm(x, params["final_norm"])
    logits = _logits(x[:, -1], params, cfg, rng)
    lengths = jnp.full((b,), s, jnp.int32)
    return cst(logits, "batch", "vocab"), cache, lengths
