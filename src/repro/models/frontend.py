"""Stub modality frontends (per the assignment: [audio]/[vlm] entries specify
the transformer BACKBONE only; the frontend supplies precomputed frame/patch
embeddings).

* musicgen-large: EnCodec tokenizer + codebook interleaving -> we supply
  per-frame embeddings of shape (batch, frames, d_model) directly.
* chameleon-34b: VQ-GAN image tokens live in the text vocabulary (early
  fusion), so inputs stay token ids; the stub marks a modality segment map.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models import layers
from repro.models.params import ParamSpec


def frontend_specs(cfg):
    """Learned output projection of the embeddings frontend: precomputed
    frame/patch embeddings map into the backbone's residual space through
    one (d_model, d_model) matmul — a matmul SITE like any other, so
    multimodal inputs exercise the SC substrate from the first layer."""
    d = cfg.d_model
    return {"proj": ParamSpec((d, d), ("embed", None), "scaled")}


def project_embeddings(x, p, cfg, key=None):
    """Route frontend embeddings (b, s, d) through the output projection
    on the configured substrate (site ``frontend_proj``)."""
    return layers.dense(x, p["proj"], cfg,
                        layers.site_key(key, "frontend_proj"),
                        site="frontend_proj")


def audio_frame_embeddings(key, batch: int, frames: int, d_model: int,
                           dtype=jnp.bfloat16):
    """Stand-in for the EnCodec front end: precomputed frame embeddings."""
    return (jax.random.normal(key, (batch, frames, d_model), jnp.float32)
            * 0.02).astype(dtype)


def vq_token_ids(key, batch: int, seq: int, vocab: int,
                 image_span: tuple[int, int] = (16, 272)):
    """Early-fusion token stream: text ids with an image-token span
    (chameleon's VQ codes are ordinary ids in the shared vocabulary)."""
    toks = jax.random.randint(key, (batch, seq), 0, vocab, jnp.int32)
    modality = jnp.zeros((batch, seq), jnp.int32)
    lo, hi = image_span
    hi = min(hi, seq)
    modality = modality.at[:, lo:hi].set(1)
    return toks, modality
