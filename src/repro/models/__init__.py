from repro.models import (  # noqa: F401
    attention, frontend, layers, lm, moe, params, ssm)
