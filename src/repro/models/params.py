"""Parameter declaration system: shapes + logical axes in one place.

Every module declares its parameters as a pytree of :class:`ParamSpec`
(shape, per-dimension *logical axis names*, initializer). From that single
declaration the framework derives

  * materialized parameters        (``init_params`` — real training)
  * ShapeDtypeStruct stand-ins     (``abstract_params`` — the dry-run)
  * ``PartitionSpec`` trees        (``partition_specs`` + sharding rules)

which keeps model code, distribution config, and the launcher from ever
disagreeing about a tensor's layout (the MaxText "logical axis rules"
pattern, reimplemented minimally).
"""

from __future__ import annotations

import dataclasses
import math
from typing import Any

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec


@dataclasses.dataclass(frozen=True)
class ParamSpec:
    shape: tuple
    axes: tuple          # logical axis name (or None) per dimension
    init: str = "normal"  # normal | zeros | ones | scaled (fan-in)
    dtype: Any = None     # overrides the model-wide param dtype

    def __post_init__(self):
        assert len(self.shape) == len(self.axes), (self.shape, self.axes)


def _is_spec(x):
    return isinstance(x, ParamSpec)


def tree_map_specs(f, tree):
    return jax.tree.map(f, tree, is_leaf=_is_spec)


def init_params(key, specs, dtype=jnp.float32):
    """Materialize a ParamSpec tree into real arrays."""
    leaves, treedef = jax.tree.flatten(specs, is_leaf=_is_spec)
    keys = jax.random.split(key, len(leaves))
    out = []
    for k, s in zip(keys, leaves):
        dt = s.dtype or dtype
        if s.init == "zeros":
            v = jnp.zeros(s.shape, dt)
        elif s.init == "ones":
            v = jnp.ones(s.shape, dt)
        elif s.init == "scaled":
            fan_in = s.shape[-2] if len(s.shape) >= 2 else s.shape[-1]
            v = (jax.random.normal(k, s.shape, jnp.float32)
                 * (1.0 / math.sqrt(fan_in))).astype(dt)
        else:
            v = (jax.random.normal(k, s.shape, jnp.float32) * 0.02).astype(dt)
        out.append(v)
    return jax.tree.unflatten(treedef, out)


def abstract_params(specs, dtype=jnp.float32):
    """ShapeDtypeStruct tree — no allocation; what the dry-run lowers with."""
    return tree_map_specs(
        lambda s: jax.ShapeDtypeStruct(s.shape, s.dtype or dtype), specs)


def partition_specs(specs, rules: dict):
    """Map logical axes -> mesh axes per ``rules`` ({logical: mesh|None}).

    A logical axis missing from the rules maps to None (replicated). A rule
    is dropped for a given tensor dimension if the dimension size does not
    divide evenly over the mesh axis — the caller passes mesh axis sizes via
    rules' companion ``sizes`` entry (see sharding/rules.py helpers).
    """
    sizes = rules.get("__sizes__", {})

    def one(s: ParamSpec):
        entries = []
        for dim, ax in zip(s.shape, s.axes):
            mesh_ax = rules.get(ax)
            if mesh_ax is None:
                entries.append(None)
                continue
            size = sizes.get(mesh_ax)
            if isinstance(mesh_ax, tuple):
                size = math.prod(sizes.get(a, 1) for a in mesh_ax)
            if size and dim % size != 0:
                entries.append(None)       # indivisible -> replicate this dim
            else:
                entries.append(mesh_ax)
        return PartitionSpec(*entries)

    return tree_map_specs(one, specs)
