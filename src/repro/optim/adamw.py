"""AdamW with cosine schedule, global-norm clipping, and quantized state.

Optimizer-state dtype is configurable (``f32`` | ``bf16`` | ``int8``): at
400B parameters the f32 m/v pair alone is 3.2 TB — quantized state is what
lets llama4-maverick fit the 256-chip pod (see EXPERIMENTS §Dry-run). int8
states store a per-tensor absmax scale alongside the quantized payload;
decode-update-encode happens in f32 inside the update, so quantization
error does not accumulate in the math, only in the storage.
"""

from __future__ import annotations

import dataclasses
import math

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10_000
    state_dtype: str = "f32"      # f32 | bf16 | int8


def cosine_lr(cfg: AdamWConfig, step):
    warm = jnp.minimum(step / jnp.maximum(cfg.warmup_steps, 1), 1.0)
    progress = jnp.clip((step - cfg.warmup_steps)
                        / max(cfg.total_steps - cfg.warmup_steps, 1), 0.0, 1.0)
    return cfg.lr * warm * 0.5 * (1.0 + jnp.cos(math.pi * progress))


# ---------------------------- state (de)quantization ------------------------


def _encode(v, kind: str):
    if kind == "f32":
        return v.astype(jnp.float32)
    if kind == "bf16":
        return v.astype(jnp.bfloat16)
    scale = jnp.maximum(jnp.max(jnp.abs(v)), 1e-20) / 127.0
    q = jnp.clip(jnp.round(v / scale), -127, 127).astype(jnp.int8)
    return {"q": q, "scale": scale.astype(jnp.float32)}


def _decode(enc, kind: str):
    if kind in ("f32", "bf16"):
        return enc.astype(jnp.float32)
    return enc["q"].astype(jnp.float32) * enc["scale"]


# ---------------------------- init / update ---------------------------------


def adamw_init(params, cfg: AdamWConfig):
    zeros = jax.tree.map(lambda p: _encode(jnp.zeros(p.shape, jnp.float32),
                                           cfg.state_dtype), params)
    return {"m": zeros,
            "v": jax.tree.map(
                lambda p: _encode(jnp.zeros(p.shape, jnp.float32),
                                  cfg.state_dtype), params),
            "step": jnp.zeros((), jnp.int32)}


def _global_norm(tree):
    return jnp.sqrt(sum(jnp.sum(jnp.square(v.astype(jnp.float32)))
                        for v in jax.tree.leaves(tree)))


def adamw_update(grads, state, params, cfg: AdamWConfig):
    """One AdamW step. Returns (new_params, new_state, metrics)."""
    step = state["step"] + 1
    gnorm = _global_norm(grads)
    clip = jnp.minimum(1.0, cfg.clip_norm / jnp.maximum(gnorm, 1e-12))
    lr = cosine_lr(cfg, step)
    bc1 = 1.0 - cfg.b1 ** step.astype(jnp.float32)
    bc2 = 1.0 - cfg.b2 ** step.astype(jnp.float32)

    is_quant = lambda x: isinstance(x, dict) and "q" in x  # noqa: E731

    def upd(p, g, m_enc, v_enc):
        g = g.astype(jnp.float32) * clip
        m = cfg.b1 * _decode(m_enc, cfg.state_dtype) + (1 - cfg.b1) * g
        v = cfg.b2 * _decode(v_enc, cfg.state_dtype) + (1 - cfg.b2) * g * g
        mhat = m / bc1
        vhat = v / bc2
        delta = mhat / (jnp.sqrt(vhat) + cfg.eps) + cfg.weight_decay \
            * p.astype(jnp.float32)
        new_p = (p.astype(jnp.float32) - lr * delta).astype(p.dtype)
        return new_p, _encode(m, cfg.state_dtype), _encode(v, cfg.state_dtype)

    # Depth-stacked leaves (n_layers, ...) update in CHUNKS along the stack
    # axis: the math is elementwise, so slicing is exact, and the f32
    # staging temps (decode/convert buffers) shrink by the chunk count --
    # at 400B the full-stack f32 temporaries were tens of GB/device of the
    # HBM peak (EXPERIMENTS.md section Perf, iteration 4). A static Python
    # loop with dynamic-update-slice keeps in-place donation intact (a
    # lax.map here double-buffers the whole stack instead: measured +23
    # GB/device -- the refuted first attempt of iteration 4). int8 state
    # keeps the direct path (per-tensor scales are not sliceable).
    STACK_CHUNKS = 8

    def upd_maybe_chunked(p, g, m_enc, v_enc):
        chunkable = (p.ndim >= 3 and 1 < p.shape[0] <= 512
                     and p.shape[0] % STACK_CHUNKS == 0
                     and not is_quant(m_enc) and p.size >= (1 << 24))
        if not chunkable:
            return upd(p, g, m_enc, v_enc)
        n = p.shape[0] // STACK_CHUNKS
        new_p, new_m, new_v = p, m_enc, v_enc
        for c in range(STACK_CHUNKS):
            sl = (slice(c * n, (c + 1) * n),)
            cp, cm, cv = upd(p[sl], g[sl], m_enc[sl], v_enc[sl])
            new_p = jax.lax.dynamic_update_slice_in_dim(new_p, cp, c * n, 0)
            new_m = jax.lax.dynamic_update_slice_in_dim(new_m, cm, c * n, 0)
            new_v = jax.lax.dynamic_update_slice_in_dim(new_v, cv, c * n, 0)
        return new_p, new_m, new_v

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = treedef.flatten_up_to(grads)
    flat_m = jax.tree.flatten(state["m"], is_leaf=is_quant)[0]
    flat_v = jax.tree.flatten(state["v"], is_leaf=is_quant)[0]
    out = [upd_maybe_chunked(p, g, m, v) for p, g, m, v in
           zip(flat_p, flat_g, flat_m, flat_v)]
    new_params = jax.tree.unflatten(treedef, [o[0] for o in out])
    new_m = jax.tree.unflatten(treedef, [o[1] for o in out])
    new_v = jax.tree.unflatten(treedef, [o[2] for o in out])
    metrics = {"grad_norm": gnorm, "lr": lr}
    return new_params, {"m": new_m, "v": new_v, "step": step}, metrics
